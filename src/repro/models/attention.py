"""Attention family: GQA (full / sliding-window / cross) and MLA.

Memory discipline: every prefill/train path uses **blockwise online-softmax
attention** (lax.scan over KV blocks, running (m, l, acc) statistics) so the
(S, S) score matrix is never materialized — mandatory for the 32k-prefill
and 4k×256 train cells, and the XLA-level analogue of a flash kernel. The
Pallas flash kernel (kernels/flash_attn.py) is swapped in on TPU for the
perf path; this scan is its oracle.

Decode reads the cache in one pass (scores are (B, H, 1, S) — small).

MLA (DeepSeek) is expressed as *latent-space attention*: cache stores only
the compressed KV latent (+ the decoupled RoPE key), queries are absorbed
into latent space (q @ W_uk), so attention is GQA with one KV head of width
(kv_lora + rope); values are the latent itself, up-projected after the
weighted sum. This is the matrix-absorption serving formulation — the whole
point of MLA's small cache — and reuses the same blockwise kernel.
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L
from ..distributed.sharding import constrain

NEG_INF = jnp.float32(-1e30)


# ---------------------------------------------------------------------------
# blockwise attention core
# ---------------------------------------------------------------------------

def _block_mask(j, kv_block, q_pos, valid_len, causal, window):
    kv_pos = j * kv_block + jnp.arange(kv_block)           # (kb,)
    ok = (kv_pos[None, :] < valid_len)
    if causal:
        ok = ok & (kv_pos[None, :] <= q_pos[:, None])
    if window is not None:
        ok = ok & (q_pos[:, None] - kv_pos[None, :] < window)
    return ok


def _flash_fwd(q, k, v, q_offset, valid_len, causal, window, kv_block,
               softcap):
    """Blockwise online-softmax forward. Returns (out, lse) with
    out (b, hkv, g, sq, dv) f32 and lse (b, hkv, g, sq) f32."""
    b, sq, hq, dk = q.shape
    sk, hkv, dv = k.shape[1], k.shape[2], v.shape[-1]
    g = hq // hkv
    qf = (q.astype(jnp.float32) / math.sqrt(dk)).reshape(b, sq, hkv, g, dk)
    nblk = k.shape[1] // kv_block
    kb = jnp.moveaxis(k.reshape(b, nblk, kv_block, hkv, dk), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nblk, kv_block, hkv, dv), 1, 0)
    q_pos = q_offset + jnp.arange(sq)

    def step(carry, blk):
        # the block counter j lives in the CARRY so nothing per-block is
        # precomputable/hoistable outside the loop
        m, l, acc, j = carry
        kj, vj = blk
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kj.astype(jnp.float32))
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        ok = _block_mask(j, kv_block, q_pos, valid_len, causal, window)
        s = jnp.where(ok[None, None, None], s, NEG_INF)    # (b,h,g,q,k)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vj.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc, j + 1), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF)
    l0 = jnp.zeros((b, hkv, g, sq))
    a0 = jnp.zeros((b, hkv, g, sq, dv))
    (m, l, acc, _), _ = jax.lax.scan(
        step, (m0, l0, a0, jnp.zeros((), jnp.int32)), (kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _flash(q, k, v, q_offset, valid_len, causal, window, kv_block, softcap):
    out, _ = _flash_fwd(q, k, v, q_offset, valid_len, causal, window,
                        kv_block, softcap)
    return out


def _flash_fwd_rule(q, k, v, q_offset, valid_len, causal, window, kv_block,
                    softcap):
    out, lse = _flash_fwd(q, k, v, q_offset, valid_len, causal, window,
                          kv_block, softcap)
    return out, (q, k, v, q_offset, valid_len, out, lse)


def _flash_bwd_rule(causal, window, kv_block, softcap, res, gout):
    """Flash backward: recompute P per block from (q, k, lse); accumulate
    dq in the carry, emit (dk_j, dv_j) per block. O(S·d) residency — the
    reason `attend` carries a custom_vjp at all (plain autodiff through the
    forward scan stacks per-block score tensors: O(S²) residuals)."""
    q, k, v, q_offset, valid_len, out, lse = res
    b, sq, hq, dk = q.shape
    sk, hkv, dv = k.shape[1], k.shape[2], v.shape[-1]
    g = hq // hkv
    scale = 1.0 / math.sqrt(dk)
    qf = (q.astype(jnp.float32) * scale).reshape(b, sq, hkv, g, dk)
    nblk = sk // kv_block
    kb = jnp.moveaxis(k.reshape(b, nblk, kv_block, hkv, dk), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nblk, kv_block, hkv, dv), 1, 0)
    q_pos = q_offset + jnp.arange(sq)
    go = gout.astype(jnp.float32)                          # (b,h,g,sq,dv)
    # delta = rowsum(dout * out)
    delta = jnp.sum(go * out, axis=-1)                     # (b,h,g,sq)

    def step(carry, blk):
        dq, j = carry
        kj, vj = blk
        kjf, vjf = kj.astype(jnp.float32), vj.astype(jnp.float32)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kjf)
        if softcap:
            t = jnp.tanh(s / softcap)
            s_capped = t * softcap
        else:
            s_capped = s
        ok = _block_mask(j, kv_block, q_pos, valid_len, causal, window)
        s_capped = jnp.where(ok[None, None, None], s_capped, NEG_INF)
        p = jnp.exp(s_capped - lse[..., None])             # (b,h,g,q,k)
        dv_j = jnp.einsum("bhgqk,bhgqd->bkhd", p, go)
        dp = jnp.einsum("bhgqd,bkhd->bhgqk", go, vjf)
        ds = p * (dp - delta[..., None])
        if softcap:
            ds = ds * (1.0 - t * t)
        ds = jnp.where(ok[None, None, None], ds, 0.0)
        # s = (q·scale)ᵀk  ⇒  ∂s/∂q = k·scale, ∂s/∂k = q·scale (= qf)
        dq = dq + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kjf) * scale
        dk_j = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qf)
        return (dq, j + 1), (dk_j, dv_j)

    dq0 = jnp.zeros((b, sq, hkv, g, dk))
    (dq, _), (dks, dvs) = jax.lax.scan(
        step, (dq0, jnp.zeros((), jnp.int32)), (kb, vb))
    dq = dq.reshape(b, sq, hq, dk).astype(q.dtype)
    dk_out = jnp.moveaxis(dks, 0, 1).reshape(b, sk, hkv, dk).astype(k.dtype)
    dv_out = jnp.moveaxis(dvs, 0, 1).reshape(b, sk, hkv, dv).astype(v.dtype)
    return dq, dk_out, dv_out, None, None


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def attend(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
           window: int | None = None, q_offset=0,
           kv_valid_len=None, kv_block: int = 512,
           softcap: float = 0.0) -> jax.Array:
    """Blockwise online-softmax ("flash") attention with a custom VJP.

    q (B, Sq, Hq, dk)   k (B, Sk, Hkv, dk)   v (B, Sk, Hkv, dv),
    Hq % Hkv == 0. Returns (B, Sq, Hq, dv) in q.dtype.
    q_offset: absolute position of q[0] (decode/chunked prefill).
    kv_valid_len: mask keys at positions >= this (cache decode).
    """
    b, sq, hq, dk = q.shape
    sk, hkv, dv = k.shape[1], k.shape[2], v.shape[-1]
    g = hq // hkv
    kv_block = min(kv_block, sk)
    pad = (-sk) % kv_block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    q_offset = jnp.asarray(q_offset, jnp.int32)
    valid_len = jnp.asarray(sk if kv_valid_len is None else kv_valid_len,
                            jnp.int32)
    out = _flash(q, k, v, q_offset, valid_len, causal, window, kv_block,
                 softcap)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, hq, dv)
    return out.astype(q.dtype)


def attend_ref(q, k, v, *, causal, window=None, q_offset=0,
               kv_valid_len=None, softcap: float = 0.0):
    """Naive O(S²)-memory oracle for tests."""
    return attend_onepass(q, k, v, causal=causal, window=window,
                          q_offset=q_offset, kv_valid_len=kv_valid_len,
                          softcap=softcap)


def attend_onepass(q, k, v, *, causal, window=None, q_offset=0,
                   kv_valid_len=None, kv_positions=None,
                   softcap: float = 0.0):
    """Single-pass softmax attention (decode: Sq is tiny).

    kv_positions: explicit absolute position per cache slot (rolling window
    caches); entries < 0 are masked; causal/window masking is implied by the
    rolling-buffer invariant and skipped."""
    b, sq, hq, dk = q.shape
    sk, hkv, dv = k.shape[1], k.shape[2], v.shape[-1]
    g = hq // hkv
    qf = (q.astype(jnp.float32) / math.sqrt(dk)).reshape(b, sq, hkv, g, dk)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = q_offset + jnp.arange(sq)
    if kv_positions is not None:
        ok = jnp.broadcast_to((kv_positions >= 0)[None, :], (sq, sk))
    else:
        kv_pos = jnp.arange(sk)
        ok = jnp.ones((sq, sk), bool) if kv_valid_len is None else \
            jnp.broadcast_to(kv_pos[None, :] < kv_valid_len, (sq, sk))
        if causal:
            ok = ok & (kv_pos[None, :] <= q_pos[:, None])
        if window is not None:
            ok = ok & (q_pos[:, None] - kv_pos[None, :] < window)
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, hq, dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array       # (B, Smax, Hkv, dk)
    v: jax.Array       # (B, Smax, Hkv, dv)
    pos: jax.Array     # () int32 — tokens already cached


def gqa_init(key, cfg):
    """Projections are stored 3-D — (d, H, hd) / (H, hd, d) — with the
    head axis marked for 'model'. The divisibility fallback then reasons
    about HEAD counts, not flattened columns: a flattened (d, H·hd) weight
    whose column count happens to divide TP gets sharded mid-head, and XLA
    must all-reduce every (S, S) score tile of the partial contraction —
    the dominant collective in the baseline whisper/internvl/GQA cells."""
    d, hq, hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.head_dim or d // hq
    ks = jax.random.split(key, 4)
    p, s = {}, {}

    def head_w(k, shape, spec, scale):
        w = (jax.random.truncated_normal(k, -2, 2, shape, jnp.float32)
             * scale).astype(cfg.dtype)
        return w, spec

    p["wq"], s["wq"] = head_w(ks[0], (d, hq, hd), P(None, L.MODEL, None),
                              1.0 / math.sqrt(d))
    p["wk"], s["wk"] = head_w(ks[1], (d, hkv, hd), P(None, L.MODEL, None),
                              1.0 / math.sqrt(d))
    p["wv"], s["wv"] = head_w(ks[2], (d, hkv, hd), P(None, L.MODEL, None),
                              1.0 / math.sqrt(d))
    p["wo"], s["wo"] = head_w(ks[3], (hq, hd, d), P(L.MODEL, None, None),
                              1.0 / math.sqrt(hq * hd))
    return p, s


def _proj_heads(x, w):
    y = jnp.einsum("bsd,dhk->bshk", x, w)
    return constrain(y, L.DATA, None, L.MODEL, None)


def gqa_apply(p, x, cfg, *, positions, cache: KVCache | None = None,
              window=None, kv_override=None, causal: bool = True):
    """x (B, S, d). Train/prefill when cache is None or being filled;
    decode when S == 1 against an existing cache. kv_override: (k, v)
    encoder memory for cross-attention (positions ignored for kv).

    Window caches may be ROLLING: allocated with `window` slots, written
    modulo window; kv slot positions are then reconstructed analytically.
    """
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    hd = cfg.head_dim or cfg.d_model // hq
    b, sq, _ = x.shape
    q = _proj_heads(x, p["wq"])
    if kv_override is not None:
        k, v = kv_override
        out = attend(q, k, v, causal=False, kv_block=min(512, k.shape[1]))
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache
    k = _proj_heads(x, p["wk"])
    v = _proj_heads(x, p["wv"])
    if cfg.rope_theta:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = attend(q, k, v, causal=causal, window=window)
    else:
        slots = cache.k.shape[1]
        rolling = window is not None and slots == window
        if rolling:
            if sq == 1:
                slot = cache.pos % window
                kc = jax.lax.dynamic_update_slice(
                    cache.k, k.astype(cache.k.dtype), (0, slot, 0, 0))
                vc = jax.lax.dynamic_update_slice(
                    cache.v, v.astype(cache.v.dtype), (0, slot, 0, 0))
                cache = KVCache(kc, vc, cache.pos + 1)
                # slot s holds absolute position pos - ((pos - s) mod W)
                pos = cache.pos - 1
                kv_positions = pos - (pos - jnp.arange(window)) % window
                out = attend_onepass(q, kc, vc, causal=True,
                                     q_offset=pos, kv_positions=kv_positions)
            else:
                # prefill from zero: attend over in-pass K/V, stash the tail
                out = attend(q, k, v, causal=causal, window=window,
                             q_offset=cache.pos)
                take = min(window, sq)
                idx = ((cache.pos + sq - take + jnp.arange(take)) % window)
                kc = cache.k.at[:, idx].set(k[:, -take:].astype(cache.k.dtype))
                vc = cache.v.at[:, idx].set(v[:, -take:].astype(cache.v.dtype))
                cache = KVCache(kc, vc, cache.pos + sq)
        else:
            kc = jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, cache.pos, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, cache.pos, 0, 0))
            cache = KVCache(kc, vc, cache.pos + sq)
            if sq == 1:
                out = attend_onepass(q, kc, vc, causal=True, window=window,
                                     q_offset=cache.pos - 1,
                                     kv_valid_len=cache.pos)
            else:
                out = attend(q, kc, vc, causal=True, window=window,
                             q_offset=cache.pos - sq, kv_valid_len=cache.pos)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return constrain(out, L.DATA, None, None), cache


def gqa_empty_cache(cfg, batch: int, max_len: int, dtype):
    hkv = cfg.n_kv_heads
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    z = jnp.zeros((batch, max_len, hkv, hd), dtype)
    return KVCache(z, z, jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# MLA block (DeepSeek-V2 family), latent-space (absorbed) formulation
# ---------------------------------------------------------------------------

def mla_init(key, cfg):
    d, hq = cfg.d_model, cfg.n_heads
    nope = cfg.head_dim or 128
    rope = cfg.qk_rope_dim
    lora = cfg.kv_lora_rank
    vd = cfg.mla_v_dim
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["wq"], s["wq"] = L.dense_init(ks[0], d, hq * (nope + rope), cfg.dtype,
                                    P(None, L.MODEL))
    p["wdkv"], s["wdkv"] = L.dense_init(ks[1], d, lora + rope, cfg.dtype,
                                        P(None, None))
    p["kv_norm"], s["kv_norm"] = L.norm_init(lora, "rmsnorm")
    p["wuk"], s["wuk"] = L.dense_init(ks[2], lora, hq * nope, cfg.dtype,
                                      P(None, L.MODEL))
    p["wuv"], s["wuv"] = L.dense_init(ks[3], lora, hq * vd, cfg.dtype,
                                      P(None, L.MODEL))
    p["wo"], s["wo"] = L.dense_init(ks[4], hq * vd, d, cfg.dtype,
                                    P(L.MODEL, None),
                                    scale=1.0 / math.sqrt(hq * vd))
    return p, s


def mla_apply(p, x, cfg, *, positions, cache: KVCache | None = None):
    d, hq = cfg.d_model, cfg.n_heads
    nope = cfg.head_dim or 128
    rope, lora, vd = cfg.qk_rope_dim, cfg.kv_lora_rank, cfg.mla_v_dim
    b, sq, _ = x.shape

    q = (x @ p["wq"]).reshape(b, sq, hq, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    # absorb W_uk: q into latent space -> (B, S, H, lora)
    wuk = p["wuk"].reshape(lora, hq, nope)
    q_lat = jnp.einsum("bshn,lhn->bshl", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32)).astype(x.dtype)
    q_all = jnp.concatenate([q_lat, q_rope], axis=-1)      # (B,S,H,lora+rope)
    q_all = constrain(q_all, L.DATA, None, L.MODEL, None)

    ckv = x @ p["wdkv"]                                    # (B,S,lora+rope)
    lat = L.norm_apply(p["kv_norm"], ckv[..., :lora], "rmsnorm")
    k_rope = L.apply_rope(ckv[..., None, lora:], positions, cfg.rope_theta)
    kv = jnp.concatenate([lat[..., None, :], k_rope], axis=-1)  # (B,S,1,lora+rope)
    # score scale: MLA normalizes by sqrt(nope + rope), not the latent width
    kv = kv * jnp.asarray(math.sqrt((lora + rope) / (nope + rope)), x.dtype)

    if cache is None:
        out = attend(q_all, kv, kv[..., :lora], causal=True)
    else:
        kc = jax.lax.dynamic_update_slice(
            cache.k, kv.astype(cache.k.dtype), (0, cache.pos, 0, 0))
        cache = KVCache(kc, kc, cache.pos + sq)
        fn = attend_onepass if sq == 1 else attend
        out = fn(q_all, kc, kc[..., :lora], causal=True,
                 q_offset=cache.pos - sq, kv_valid_len=cache.pos)
    # up-project values: (B,S,H,lora) x (lora, H, vd) -> (B,S,H*vd)
    wuv = p["wuv"].reshape(lora, hq, vd)
    o = jnp.einsum("bshl,lhv->bshv", out.astype(jnp.float32),
                   wuv.astype(jnp.float32)).astype(x.dtype)
    return constrain(o.reshape(b, sq, hq * vd) @ p["wo"], L.DATA, None, None), cache


def mla_empty_cache(cfg, batch: int, max_len: int, dtype):
    z = jnp.zeros((batch, max_len, 1, cfg.kv_lora_rank + cfg.qk_rope_dim), dtype)
    return KVCache(z, z, jnp.zeros((), jnp.int32))
