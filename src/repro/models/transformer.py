"""Decoder-only LM: embedding, mixer/MLP blocks, scanned layer stacks.

Layer stacking: the per-layer mixer is cfg.pattern[i % len(pattern)]
(hybrids like RecurrentGemma repeat ("rglru","rglru","lattn")). Layers are
grouped into repeating pattern units and the unit is lax.scan'ed over
stacked parameters — compile time and HLO size stay O(pattern) instead of
O(n_layers), essential for the 88-layer dry-run cells. Non-uniform heads
(first_k_dense MoE warm-up layers) and the pattern remainder run unscanned.

Caches mirror the param structure: {"prefix": [...], "groups": (slot0
stacked over n_groups, ...), "tail": [...]}.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as A
from . import layers as L
from . import moe as M
from . import rglru as R
from . import ssm as S
from .config import ModelConfig
from ..distributed.sharding import constrain

ATTN_KINDS = ("attn", "swa", "lattn", "mla")


# ---------------------------------------------------------------------------
# one block
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, mixer: str, mlp: str):
    k1, k2 = jax.random.split(key)
    p, s = {}, {}
    p["norm1"], s["norm1"] = L.norm_init(cfg.d_model, cfg.norm)
    if mixer == "mla":
        p["mixer"], s["mixer"] = A.mla_init(k1, cfg)
    elif mixer in ("attn", "swa", "lattn"):
        p["mixer"], s["mixer"] = A.gqa_init(k1, cfg)
    elif mixer == "mamba":
        p["mixer"], s["mixer"] = S.ssd_init(k1, cfg)
    elif mixer == "rglru":
        p["mixer"], s["mixer"] = R.rglru_init(k1, cfg)
    else:
        raise ValueError(mixer)
    if mlp == "dense":
        p["norm2"], s["norm2"] = L.norm_init(cfg.d_model, cfg.norm)
        p["mlp"], s["mlp"] = L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.dtype,
                                        cfg.mlp_kind)
    elif mlp == "moe":
        p["norm2"], s["norm2"] = L.norm_init(cfg.d_model, cfg.norm)
        p["moe"], s["moe"] = M.moe_init(k2, cfg)
    return p, s


def block_apply(p, x, cfg: ModelConfig, mixer: str, mlp: str, *,
                positions, cache=None):
    """Returns (x, new_cache, aux_loss)."""
    h = L.norm_apply(p["norm1"], x, cfg.norm)
    if mixer == "mla":
        y, cache = A.mla_apply(p["mixer"], h, cfg, positions=positions,
                               cache=cache)
    elif mixer in ("attn", "swa", "lattn"):
        win = cfg.window if mixer in ("swa", "lattn") else None
        y, cache = A.gqa_apply(p["mixer"], h, cfg, positions=positions,
                               cache=cache, window=win)
    elif mixer == "mamba":
        y, cache = S.ssd_apply(p["mixer"], h, cfg, cache=cache)
    else:
        y, cache = R.rglru_apply(p["mixer"], h, cfg, cache=cache)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if mlp == "dense":
        h = L.norm_apply(p["norm2"], x, cfg.norm)
        act = "silu" if cfg.mlp_kind == "swiglu" else "gelu"
        x = x + L.mlp_apply(p["mlp"], h, cfg.mlp_kind, act)
    elif mlp == "moe":
        h = L.norm_apply(p["norm2"], x, cfg.norm)
        y, aux = M.moe_apply(p["moe"], h, cfg)
        x = x + y
    return x, cache, aux


def block_empty_cache(cfg: ModelConfig, mixer: str, batch: int, max_len: int,
                      dtype):
    if mixer == "mla":
        return A.mla_empty_cache(cfg, batch, max_len, dtype)
    if mixer in ("attn", "swa", "lattn"):
        # window-bounded mixers only ever read the trailing `window` slots
        ln = max_len if cfg.window is None or mixer == "attn" \
            else min(max_len, cfg.window)
        return A.gqa_empty_cache(cfg, batch, ln, dtype)
    if mixer == "mamba":
        return S.ssm_empty_cache(cfg, batch, dtype)
    return R.rglru_empty_cache(cfg, batch, dtype)


# ---------------------------------------------------------------------------
# stacked init
# ---------------------------------------------------------------------------

def _stack_init(fn, key, n: int):
    """vmap a params-producing init over n keys; lift specs with leading None."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: fn(k)[0])(keys)
    specs = fn(key)[1]
    lifted = jax.tree.map(lambda s: P(None, *s), specs,
                          is_leaf=lambda x: isinstance(x, P))
    return params, lifted


def decoder_init(key, cfg: ModelConfig):
    n_pre, n_groups, n_tail = cfg.layer_plan()
    plen = len(cfg.pattern)
    keys = jax.random.split(key, 8)
    p: dict[str, Any] = {}
    s: dict[str, Any] = {}
    p["embed"], s["embed"] = L.embed_init(keys[0], cfg.vocab_padded,
                                          cfg.d_model, cfg.dtype)
    p["final_norm"], s["final_norm"] = L.norm_init(cfg.d_model, cfg.norm)
    if not cfg.tie_embeddings:
        p["lm_head"], s["lm_head"] = L.dense_init(
            keys[1], cfg.d_model, cfg.vocab_padded, cfg.dtype, P(None, L.MODEL))
    if cfg.n_patches:
        p["patch_proj"], s["patch_proj"] = L.dense_init(
            keys[2], cfg.d_model, cfg.d_model, cfg.dtype, P(None, None))

    p["prefix"], s["prefix"] = [], []
    for i in range(n_pre):
        bp, bs = block_init(jax.random.fold_in(keys[3], i), cfg,
                            cfg.mixer_of(i), cfg.mlp_of(i))
        p["prefix"].append(bp); s["prefix"].append(bs)

    p["groups"], s["groups"] = [], []
    for j in range(plen):
        li = n_pre + j
        if n_groups > 0:
            bp, bs = _stack_init(
                lambda k, li=li: block_init(k, cfg, cfg.mixer_of(li),
                                            cfg.mlp_of(li)),
                jax.random.fold_in(keys[4], j), n_groups)
        else:
            bp, bs = None, None
        p["groups"].append(bp); s["groups"].append(bs)

    p["tail"], s["tail"] = [], []
    for t in range(n_tail):
        li = n_pre + n_groups * plen + t
        bp, bs = block_init(jax.random.fold_in(keys[5], t), cfg,
                            cfg.mixer_of(li), cfg.mlp_of(li))
        p["tail"].append(bp); s["tail"].append(bs)
    return p, s


def decoder_empty_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    n_pre, n_groups, n_tail = cfg.layer_plan()
    plen = len(cfg.pattern)

    def one(mixer):
        return block_empty_cache(cfg, mixer, batch, max_len, dtype)

    def stack(mixer, n):
        c = one(mixer)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n, *a.shape)) if a.ndim else
            jnp.zeros((n,), a.dtype), c)

    cache = {
        "prefix": [one(cfg.mixer_of(i)) for i in range(n_pre)],
        "groups": [stack(cfg.mixer_of(n_pre + j), n_groups) if n_groups else None
                   for j in range(plen)],
        "tail": [one(cfg.mixer_of(n_pre + n_groups * plen + t))
                 for t in range(n_tail)],
    }
    return cache


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _vocab_mask(cfg) -> jax.Array:
    """(Vpad,) additive mask: -inf on padding columns."""
    v = jnp.arange(cfg.vocab_padded)
    return jnp.where(v < cfg.vocab_size, 0.0, -1e30).astype(jnp.float32)


def decoder_forward(params, cfg: ModelConfig, tokens, *, cache=None,
                    patches=None, logits_slice: int | None = None):
    """tokens (B, S) int32. cache=None → train forward (full logits).
    With cache → prefill/decode; logits for the last `logits_slice` tokens
    (default: all for train, 1 for cached paths).

    Returns (logits, new_cache, aux_loss_sum).
    """
    n_pre, n_groups, n_tail = cfg.layer_plan()
    plen = len(cfg.pattern)
    b, s_tok = tokens.shape
    if cfg.n_patches and patches is not None:      # prefill/train: prepend patches
        tx = params["embed"][tokens]
        px = (patches.astype(cfg.dtype) @ params["patch_proj"])
        x = jnp.concatenate([px, tx], axis=1)
    else:
        assert not (cfg.n_patches and cache is None), \
            "vlm arch needs patch embeddings for training"
        x = params["embed"][tokens]
    x = constrain(x, L.DATA, None, None)
    seq = x.shape[1]
    pos0 = jnp.zeros((), jnp.int32) if cache is None else _cache_pos(cache)
    positions = (pos0 + jnp.arange(seq))[None, :]          # (1, S)

    aux_total = jnp.zeros((), jnp.float32)
    new_cache = {"prefix": [], "groups": [], "tail": []} if cache is not None \
        else None

    def seq_constrain(xx):
        # Megatron-SP: the residual stream (and the per-layer remat carry)
        # lives S-sharded over 'model'; attention/FFN gather transiently.
        if cfg.seq_shard:
            return constrain(xx, L.DATA, L.MODEL, None)
        return xx

    x = seq_constrain(x)

    def run_block(p, xx, mixer, mlp, c):
        xx, c2, aux = block_apply(p, xx, cfg, mixer, mlp,
                                  positions=positions, cache=c)
        return seq_constrain(xx), c2, aux

    for i in range(n_pre):
        c = cache["prefix"][i] if cache is not None else None
        x, c2, aux = run_block(params["prefix"][i], x, cfg.mixer_of(i),
                               cfg.mlp_of(i), c)
        aux_total += aux
        if cache is not None:
            new_cache["prefix"].append(c2)

    if n_groups > 0:
        mixers = [cfg.mixer_of(n_pre + j) for j in range(plen)]
        mlps = [cfg.mlp_of(n_pre + j) for j in range(plen)]

        def group_body(carry, xs):
            xx, aux_acc = carry
            slot_params, slot_caches = xs
            outs = []
            for j in range(plen):
                c = slot_caches[j] if slot_caches is not None else None
                xx, c2, aux = run_block(slot_params[j], xx, mixers[j],
                                        mlps[j], c)
                aux_acc = aux_acc + aux
                outs.append(c2)
            ys = tuple(outs) if slot_caches is not None else None
            return (xx, aux_acc), ys

        body = jax.checkpoint(group_body) if cfg.remat else group_body
        slot_params = tuple(params["groups"][j] for j in range(plen))
        slot_caches = tuple(cache["groups"][j] for j in range(plen)) \
            if cache is not None else None
        (x, aux_total), group_caches = jax.lax.scan(
            body, (x, aux_total), (slot_params, slot_caches))
        if cache is not None:
            new_cache["groups"] = list(group_caches)
        else:
            new_cache = None

    for t in range(n_tail):
        li = n_pre + n_groups * plen + t
        c = cache["tail"][t] if cache is not None else None
        x, c2, aux = run_block(params["tail"][t], x, cfg.mixer_of(li),
                               cfg.mlp_of(li), c)
        aux_total += aux
        if cache is not None:
            new_cache["tail"].append(c2)

    x = L.norm_apply(params["final_norm"], x, cfg.norm)
    if logits_slice is not None:
        x = x[:, -logits_slice:]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    logits = L.logits_softcap(logits, cfg.logit_softcap)
    logits = logits + _vocab_mask(cfg).astype(logits.dtype)
    return constrain(logits, L.DATA, None, L.MODEL), new_cache, aux_total


def _cache_pos(cache):
    for part in ("prefix", "tail"):
        if cache[part]:
            return cache[part][0].pos
    for g in cache["groups"]:
        if g is not None:
            return g.pos[0]
    raise ValueError("empty cache")
