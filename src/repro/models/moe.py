"""Mixture-of-Experts FFN: top-k routing, sort-based capacity dispatch.

Dispatch is *per batch row* and one-hot-free: routed (token, expert-choice)
pairs are argsorted by expert id, positions within each expert come from a
searchsorted rank trick, and tokens scatter into a static (E, C, d) capacity
buffer — no (T, E, C) dispatch tensor is ever built, so compiled FLOPs stay
proportional to *active* parameters (the roofline MODEL_FLOPS/HLO_FLOPs
ratio stays honest).

Sharding: tokens/buffers carry the batch ('data') axis; expert weights are
expert-sliced over 'model' (each chip holds a d_ff slice of EVERY expert —
Megatron-style TP inside each expert). This avoids all-to-all on the
dispatch path entirely; the alternative expert-parallel layout (experts over
'model', all-to-all dispatch) is discussed in DESIGN.md §5 and is a perf-
iteration knob.

Aux loss: Switch-style load-balance loss, returned for the train loop.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L
from ..distributed.sharding import constrain

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p, s = {}, {}
    p["router"] = (jax.random.normal(ks[0], (d, e), jnp.float32) * 0.02)
    s["router"] = P(None, None)
    init = jax.nn.initializers.truncated_normal(1.0 / math.sqrt(d))
    p["wi"] = init(ks[1], (e, d, f), jnp.float32).astype(cfg.dtype)
    p["wg"] = init(ks[2], (e, d, f), jnp.float32).astype(cfg.dtype)
    p["wo"] = (jax.nn.initializers.truncated_normal(1.0 / math.sqrt(f))(
        ks[3], (e, f, d), jnp.float32)).astype(cfg.dtype)
    s["wi"] = P(None, None, L.MODEL)
    s["wg"] = P(None, None, L.MODEL)
    s["wo"] = P(None, L.MODEL, None)
    if cfg.n_shared_experts:
        p["shared"], s["shared"] = L.mlp_init(
            ks[4], d, cfg.n_shared_experts * (cfg.moe_d_ff or cfg.d_ff),
            cfg.dtype, cfg.mlp_kind)
    return p, s


def _route_row(gates_topk_idx: jax.Array, k: int, capacity: int, n_experts: int):
    """One batch row. gates_topk_idx (S, k) -> (dest (S*k,), order info).

    dest[i] = expert*C + slot for routed copy i (flattened (S, k)), or
    E*C (dropped) when the expert's capacity is exceeded.
    """
    sk = gates_topk_idx.size
    flat_e = gates_topk_idx.reshape(sk)
    order = jnp.argsort(flat_e, stable=True)               # token-prio within expert
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(n_experts), side="left")
    slot = jnp.arange(sk) - first[sorted_e]                # rank within expert
    ok = slot < capacity
    dest_sorted = jnp.where(ok, sorted_e * capacity + slot,
                            n_experts * capacity)
    # scatter back to flat routed order
    dest = jnp.zeros((sk,), jnp.int32).at[order].set(dest_sorted.astype(jnp.int32))
    return dest


def moe_apply(p, x, cfg):
    """x (B, S, d) -> (out (B, S, d), aux_loss ())."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_active
    f = cfg.moe_d_ff or cfg.d_ff
    cap = int(math.ceil(s * k / e * cfg.capacity_factor))

    logits = x.astype(jnp.float32) @ p["router"]           # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                 # (B,S,k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e (frac tokens to e) * (mean prob of e)
    frac = jnp.mean(jax.nn.one_hot(top_i[..., 0], e, dtype=jnp.float32),
                    axis=(0, 1))
    aux = e * jnp.sum(frac * jnp.mean(probs, axis=(0, 1)))

    dest = jax.vmap(lambda ti: _route_row(ti, k, cap, e))(top_i)   # (B, S*k)

    # scatter tokens into (B, E*C, d) capacity buffers (extra row = drop sink)
    xk = jnp.repeat(x, k, axis=1)                          # (B, S*k, d)
    buf = jnp.zeros((b, e * cap + 1, d), x.dtype)
    buf = jax.vmap(lambda bf, dd, xx: bf.at[dd].set(xx))(buf, dest, xk)
    buf = buf[:, :-1].reshape(b, e, cap, d)
    buf = constrain(buf, L.DATA, None, None, None)

    # expert FFN (expert-sliced TP over 'model' on f)
    h = jnp.einsum("becd,edf->becf", buf, p["wi"])
    g = jnp.einsum("becd,edf->becf", buf, p["wg"])
    act = "silu" if cfg.mlp_kind == "swiglu" else "gelu"
    h = L.act_fn(act)(g) * h
    h = constrain(h, L.DATA, None, None, L.MODEL)
    eo = jnp.einsum("becf,efd->becd", h, p["wo"])          # (B,E,C,d)

    # gather back + weighted combine over the k choices
    eo_flat = jnp.concatenate(
        [eo.reshape(b, e * cap, d), jnp.zeros((b, 1, d), eo.dtype)], axis=1)
    routed = jax.vmap(lambda ef, dd: ef[dd])(eo_flat, dest)  # (B, S*k, d)
    routed = routed.reshape(b, s, k, d)
    out = jnp.einsum("bskd,bsk->bsd", routed.astype(jnp.float32),
                     top_p).astype(x.dtype)

    if cfg.n_shared_experts:
        out = out + L.mlp_apply(p["shared"], x, cfg.mlp_kind,
                                "silu" if cfg.mlp_kind == "swiglu" else "gelu")
    return constrain(out, L.DATA, None, None), aux
