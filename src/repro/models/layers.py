"""Core NN primitives — functional, param-dict based, shard-spec aware.

Every init_* returns a (params, specs) pair built from the same structure:
``params`` holds jnp arrays, ``specs`` holds jax.sharding.PartitionSpec with
*logical* axis names ('data', 'model', None) resolved later by
distributed/sharding.py. Keeping specs structurally parallel to params lets
jax.tree.map pair them for jit in_shardings in the dry-run.

Dtype policy: params in cfg.dtype (bf16 by default), math in f32 where it
matters (norms, softmax, rope), outputs cast back.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

DATA = ("pod", "data")     # batch-sharding axes (pod collapses onto data when absent)
MODEL = "model"


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, spec: P,
               *, scale: float | None = None):
    """He/Glorot-ish truncated-normal linear weight + its PartitionSpec."""
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = (jax.random.truncated_normal(key, -2, 2, (d_in, d_out), jnp.float32)
         * scale).astype(dtype)
    return w, spec


def embed_init(key, vocab: int, d: int, dtype):
    w = (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)
    return w, P(MODEL, None)


def norm_init(d: int, kind: str):
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}, \
               {"scale": P(None), "bias": P(None)}
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": P(None)}


# ---------------------------------------------------------------------------
# apply helpers
# ---------------------------------------------------------------------------

def norm_apply(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return out.astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> jax.Array:
    """(dim//2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, hd) with interleaved-pair rotation; positions (..., S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                              # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (dense FFN): swiglu / geglu / plain 2-layer
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, dtype, kind: str):
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    if kind in ("swiglu", "geglu"):
        p["wi"], s["wi"] = dense_init(ks[0], d, d_ff, dtype, P(None, MODEL))
        p["wg"], s["wg"] = dense_init(ks[1], d, d_ff, dtype, P(None, MODEL))
    else:
        p["wi"], s["wi"] = dense_init(ks[0], d, d_ff, dtype, P(None, MODEL))
    p["wo"], s["wo"] = dense_init(ks[2], d_ff, d, dtype, P(MODEL, None),
                                  scale=1.0 / math.sqrt(d_ff))
    return p, s


def mlp_apply(p, x, kind: str, act: str):
    f = act_fn(act)
    if kind in ("swiglu", "geglu"):
        a = "silu" if kind == "swiglu" else "gelu"
        h = act_fn(a)(x @ p["wg"]) * (x @ p["wi"])
    else:
        h = f(x @ p["wi"])
    return h @ p["wo"]


def logits_softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return (jnp.tanh(x.astype(jnp.float32) / cap) * cap).astype(x.dtype)
