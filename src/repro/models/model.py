"""Model factory + train/serve step builders — the public modeling API.

    model = build_model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    loss = model.loss(params, batch)
    step = make_train_step(model, opt_cfg)      # jit-able, donatable
    logits, cache = model.prefill(params, tokens, cache)
    logits, cache = model.decode(params, tokens1, cache)

`batch` dicts: tokens/labels (B, S) i32; audio adds frames (B, F, d);
vlm adds patches (B, Np, d) (both modality frontends are stubs per brief).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import encdec, transformer
from .config import ModelConfig
from ..optim import adamw


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- init ----------------------------------------------------------------
    def init(self, key) -> tuple[Any, Any]:
        if self.cfg.enc_layers:
            return encdec.encdec_init(key, self.cfg)
        return transformer.decoder_init(key, self.cfg)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        """max_len counts *text* tokens; vlm patch slots are added here."""
        max_len = max_len + self.cfg.n_patches
        if self.cfg.enc_layers:
            return encdec.encdec_empty_cache(self.cfg, batch, max_len, dtype)
        return transformer.decoder_empty_cache(self.cfg, batch, max_len, dtype)

    # -- forward -------------------------------------------------------------
    def forward(self, params, batch: dict):
        """Teacher-forced full-sequence logits (training)."""
        cfg = self.cfg
        if cfg.enc_layers:
            memory = encdec.encode(params, cfg, batch["frames"])
            logits, _ = encdec.decode_forward(params, cfg, batch["tokens"],
                                              None, memory=memory)
            return logits, jnp.zeros((), jnp.float32)
        logits, _, aux = transformer.decoder_forward(
            params, cfg, batch["tokens"], patches=batch.get("patches"))
        return logits, aux

    def loss(self, params, batch: dict):
        cfg = self.cfg
        logits, aux = self.forward(params, batch)
        if cfg.n_patches:                      # vlm: text logits only
            logits = logits[:, cfg.n_patches:]
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32), labels[..., None].astype(jnp.int32),
            axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        ce = jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return ce + cfg.router_aux_coef * aux, {"ce": ce, "aux": aux}

    # -- serving -------------------------------------------------------------
    def prefill(self, params, tokens, cache, *, frames=None, patches=None):
        cfg = self.cfg
        if cfg.enc_layers:
            memory = encdec.encode(params, cfg, frames)
            ck, cv = encdec.project_cross_kv(params, cfg, memory)
            cache = encdec.EncDecCache(cache.self_kv, ck.astype(cache.cross_k.dtype),
                                       cv.astype(cache.cross_v.dtype))
            return encdec.decode_forward(params, cfg, tokens, cache,
                                         logits_slice=1)
        logits, cache, _ = transformer.decoder_forward(
            params, cfg, tokens, cache=cache, patches=patches, logits_slice=1)
        return logits, cache

    def decode(self, params, tokens, cache):
        """One decode step; tokens (B, 1)."""
        cfg = self.cfg
        if cfg.enc_layers:
            return encdec.decode_forward(params, cfg, tokens, cache,
                                         logits_slice=1)
        logits, cache, _ = transformer.decoder_forward(
            params, cfg, tokens, cache=cache, logits_slice=1)
        return logits, cache


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


# ---------------------------------------------------------------------------
# step builders (pure functions of (params, opt_state, batch) — jit outside)
# ---------------------------------------------------------------------------

def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig
                    ) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    cfg.accum_steps > 1 runs microbatched gradient accumulation via
    lax.scan (live activation memory / accum_steps)."""
    accum = model.cfg.accum_steps

    def loss_fn(params, batch):
        loss, parts = model.loss(params, batch)
        return loss, parts

    def train_step(params, opt_state, batch):
        if accum <= 1:
            (loss, parts), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                gacc, lacc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                return (jax.tree.map(jnp.add, gacc, g), lacc + l), None

            micro_batches = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros(())), micro_batches)
            grads = jax.tree.map(lambda g: (g / accum).astype(jnp.float32), gsum)
            loss = lsum / accum
            parts = {"ce": loss, "aux": jnp.zeros(())}
        params, opt_state, om = adamw.update(opt_cfg, grads, opt_state, params)
        return params, opt_state, {"loss": loss, **parts, **om}

    return train_step


def make_serve_step(model: Model) -> Callable:
    """serve_step(params, cache, tokens) -> (next_token_logits, cache) —
    the function the decode_* dry-run cells lower."""

    def serve_step(params, cache, tokens):
        logits, cache = model.decode(params, tokens, cache)
        return logits, cache

    return serve_step


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, cache, tokens, frames=None, patches=None):
        kw = {}
        if model.cfg.enc_layers:
            kw["frames"] = frames
        if model.cfg.n_patches:
            kw["patches"] = patches
        logits, cache = model.prefill(params, tokens, cache, **kw)
        return logits, cache

    return prefill_step
