"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Per-channel gated linear recurrence:

    r_t = sigmoid(W_a x_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)            (input gate)
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is linear in h, so prefill/train runs as a
jax.lax.associative_scan over the sequence (log-depth, parallel), and decode
is a single fused update — O(1) state, which is why recurrentgemma runs the
long_500k cell. Channels are embarrassingly parallel → sharded over 'model'.

The surrounding block is Griffin's recurrent block: two input projections
(gate branch: GeLU; recurrent branch: causal conv1d(4) then RG-LRU),
elementwise product, output projection.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L
from ..distributed.sharding import constrain

_C = 8.0


class RGLRUCache(NamedTuple):
    h: jax.Array        # (B, W_rnn) f32 recurrent state
    conv: jax.Array     # (B, conv_width-1, W_rnn)
    pos: jax.Array


def rglru_init(key, cfg):
    d = cfg.d_model
    w = cfg.rnn_width or d
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["wy"], s["wy"] = L.dense_init(ks[0], d, w, cfg.dtype, P(None, L.MODEL))
    p["wx"], s["wx"] = L.dense_init(ks[1], d, w, cfg.dtype, P(None, L.MODEL))
    p["conv_w"] = (jax.random.normal(ks[2], (cfg.conv_width, w), jnp.float32)
                   / math.sqrt(cfg.conv_width)).astype(cfg.dtype)
    s["conv_w"] = P(None, L.MODEL)
    p["conv_b"] = jnp.zeros((w,), cfg.dtype)
    s["conv_b"] = P(L.MODEL)
    p["wa"], s["wa"] = L.dense_init(ks[3], w, w, cfg.dtype, P(None, L.MODEL))
    p["ba"] = jnp.zeros((w,), jnp.float32); s["ba"] = P(L.MODEL)
    p["wi"], s["wi"] = L.dense_init(ks[4], w, w, cfg.dtype, P(None, L.MODEL))
    p["bi"] = jnp.zeros((w,), jnp.float32); s["bi"] = P(L.MODEL)
    # Lambda init so that a^c in [0.9, 0.999] at r=1 (paper App. A)
    u = jax.random.uniform(ks[5], (w,), minval=0.9, maxval=0.999)
    p["lam"] = jnp.log(jnp.expm1(-jnp.log(u) / _C))      # softplus^-1(-ln u / c)
    s["lam"] = P(L.MODEL)
    p["wo"], s["wo"] = L.dense_init(ks[0], w, d, cfg.dtype, P(L.MODEL, None),
                                    scale=1.0 / math.sqrt(w))
    return p, s


def _gates(p, u):
    """u (B, S, W) conv output -> (log_a, gated_input) both f32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["wa"].astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(uf @ p["wi"].astype(jnp.float32) + p["bi"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r          # (B,S,W) < 0
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return log_a, beta * (i * uf)


def _conv(u, w, b):
    width = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(u, dtype=jnp.float32)
    for i in range(width):
        out = out + pad[:, i:i + u.shape[1]].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(u.dtype)


def rglru_apply(p, x, cfg, *, cache: RGLRUCache | None = None):
    """x (B, S, d_model) -> (B, S, d_model)."""
    b, s, _ = x.shape
    if cache is not None and s == 1:
        return rglru_decode(p, x, cfg, cache)
    y = jax.nn.gelu(x @ p["wy"])                          # gate branch
    u = x @ p["wx"]
    u = _conv(u, p["conv_w"], p["conv_b"])
    u = constrain(u, L.DATA, None, L.MODEL)
    log_a, gi = _gates(p, u)

    if cache is not None:
        # seed the scan with the cached state as a virtual step 0
        log_a = jnp.concatenate([jnp.zeros_like(log_a[:, :1]), log_a], axis=1)
        gi = jnp.concatenate([cache.h.astype(jnp.float32)[:, None], gi], axis=1)

    def combine(ea, eb):
        a1, b1 = ea
        a2, b2 = eb
        return a1 + a2, jnp.exp(a2) * b1 + b2

    _, h = jax.lax.associative_scan(combine, (log_a, gi), axis=1)
    if cache is not None:
        h = h[:, 1:]
    out = constrain((h.astype(x.dtype) * y) @ p["wo"], L.DATA, None, None)
    if cache is None:
        return out, None
    new_conv = (x @ p["wx"])[:, -(cfg.conv_width - 1):]
    if s < cfg.conv_width - 1:
        new_conv = jnp.concatenate(
            [cache.conv[:, s:], new_conv], axis=1)
    return out, RGLRUCache(h[:, -1].astype(cache.h.dtype),
                           new_conv.astype(cache.conv.dtype), cache.pos + s)


def rglru_decode(p, x, cfg, cache: RGLRUCache):
    b = x.shape[0]
    y = jax.nn.gelu(x @ p["wy"])                          # (B,1,W)
    u_new = x @ p["wx"]                                   # (B,1,W)
    hist = jnp.concatenate([cache.conv, u_new], axis=1)   # (B,W_c,W)
    w = p["conv_w"].astype(jnp.float32)
    u = (jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32), w)
         + p["conv_b"].astype(jnp.float32))[:, None].astype(x.dtype)
    log_a, gi = _gates(p, u)                              # (B,1,W)
    h = jnp.exp(log_a[:, 0]) * cache.h.astype(jnp.float32) + gi[:, 0]
    out = constrain((h[:, None].astype(x.dtype) * y) @ p["wo"],
                    L.DATA, None, None)
    return out, RGLRUCache(h.astype(cache.h.dtype),
                           hist[:, 1:].astype(cache.conv.dtype),
                           cache.pos + 1)


def rglru_empty_cache(cfg, batch: int, dtype):
    w = cfg.rnn_width or cfg.d_model
    return RGLRUCache(h=jnp.zeros((batch, w), jnp.float32),
                      conv=jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
                      pos=jnp.zeros((), jnp.int32))
