"""Encoder-decoder stack (Whisper-large-v3 backbone).

The conv/mel frontend is a STUB per the brief: ``input_specs()`` feeds
precomputed frame embeddings (B, n_frames, d_model) directly into the
encoder (sinusoidal positions added here). The decoder is a standard
pre-LN transformer with causal self-attention (KV-cached), cross-attention
to the encoder memory (cross-K/V projected once at prefill and cached),
and a plain GeLU MLP; token/output embeddings are tied, LayerNorm, no RoPE
(absolute sinusoidal positions, a small deviation from Whisper's learned
decoder positions recorded in DESIGN.md).

Both stacks are lax.scan'ed over stacked layer params.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention as A
from . import layers as L
from .config import ModelConfig
from .transformer import _stack_init, _vocab_mask
from ..distributed.sharding import constrain


class EncDecCache(NamedTuple):
    self_kv: A.KVCache      # stacked (L, ...) decoder self-attention cache
    cross_k: jax.Array      # (L, B, F, Hkv, hd)
    cross_v: jax.Array


def sinusoid(positions: jax.Array, d: int) -> jax.Array:
    """(S,) -> (S, d) transformer sinusoidal embedding."""
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[:, None].astype(jnp.float32) * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _enc_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    p, s = {}, {}
    p["norm1"], s["norm1"] = L.norm_init(cfg.d_model, cfg.norm)
    p["attn"], s["attn"] = A.gqa_init(k1, cfg)
    p["norm2"], s["norm2"] = L.norm_init(cfg.d_model, cfg.norm)
    p["mlp"], s["mlp"] = L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.dtype,
                                    cfg.mlp_kind)
    return p, s


def _dec_block_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    p, s = {}, {}
    p["norm1"], s["norm1"] = L.norm_init(cfg.d_model, cfg.norm)
    p["self"], s["self"] = A.gqa_init(k1, cfg)
    p["norm_x"], s["norm_x"] = L.norm_init(cfg.d_model, cfg.norm)
    p["cross"], s["cross"] = A.gqa_init(k2, cfg)
    p["norm2"], s["norm2"] = L.norm_init(cfg.d_model, cfg.norm)
    p["mlp"], s["mlp"] = L.mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.dtype,
                                    cfg.mlp_kind)
    return p, s


def encdec_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["embed"], s["embed"] = L.embed_init(ks[0], cfg.vocab_padded,
                                          cfg.d_model, cfg.dtype)
    p["enc"], s["enc"] = _stack_init(lambda k: _enc_block_init(k, cfg),
                                     ks[1], cfg.enc_layers)
    p["enc_norm"], s["enc_norm"] = L.norm_init(cfg.d_model, cfg.norm)
    p["dec"], s["dec"] = _stack_init(lambda k: _dec_block_init(k, cfg),
                                     ks[2], cfg.n_layers)
    p["dec_norm"], s["dec_norm"] = L.norm_init(cfg.d_model, cfg.norm)
    return p, s


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames (B, F, d_model) stub embeddings -> encoder memory (B, F, d)."""
    b, f, _ = frames.shape
    x = frames.astype(cfg.dtype) + \
        sinusoid(jnp.arange(f), cfg.d_model)[None].astype(cfg.dtype)
    x = constrain(x, L.DATA, None, None)
    positions = jnp.arange(f)[None]

    def body(xx, lp):
        h = L.norm_apply(lp["norm1"], xx, cfg.norm)
        y, _ = A.gqa_apply(lp["attn"], h, cfg, positions=positions,
                           causal=False)
        xx = xx + y
        h = L.norm_apply(lp["norm2"], xx, cfg.norm)
        return xx + L.mlp_apply(lp["mlp"], h, cfg.mlp_kind, cfg.act), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc"])
    return L.norm_apply(params["enc_norm"], x, cfg.norm)


def project_cross_kv(params, cfg: ModelConfig, memory: jax.Array):
    """Per-decoder-layer cross K/V from the encoder memory (prefill-once)."""
    hkv, hd = cfg.n_kv_heads, cfg.hd
    b, f, _ = memory.shape

    def body(_, lp):
        k = jnp.einsum("bsd,dhk->bshk", memory, lp["cross"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", memory, lp["cross"]["wv"])
        return None, (k, v)

    _, (ck, cv) = jax.lax.scan(body, None, params["dec"])
    return ck, cv           # (L, B, F, Hkv, hd)


def decode_forward(params, cfg: ModelConfig, tokens, cache: EncDecCache | None,
                   *, memory=None, logits_slice: int | None = None):
    """Decoder pass. cache=None → teacher-forced training (memory required);
    otherwise prefill/decode against the cache (cross K/V precomputed).

    Returns (logits, new_cache)."""
    b, sq = tokens.shape
    pos0 = jnp.zeros((), jnp.int32) if cache is None else cache.self_kv.pos[0]
    x = params["embed"][tokens] + \
        sinusoid(pos0 + jnp.arange(sq), cfg.d_model)[None].astype(cfg.dtype)
    x = constrain(x, L.DATA, None, None)
    positions = (pos0 + jnp.arange(sq))[None]

    if cache is None:
        assert memory is not None
        hkv, hd = cfg.n_kv_heads, cfg.hd

        def body(xx, lp):
            h = L.norm_apply(lp["norm1"], xx, cfg.norm)
            y, _ = A.gqa_apply(lp["self"], h, cfg, positions=positions)
            xx = xx + y
            h = L.norm_apply(lp["norm_x"], xx, cfg.norm)
            k = jnp.einsum("bsd,dhk->bshk", memory, lp["cross"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", memory, lp["cross"]["wv"])
            y, _ = A.gqa_apply(lp["cross"], h, cfg, positions=positions,
                               kv_override=(k, v))
            xx = xx + y
            h = L.norm_apply(lp["norm2"], xx, cfg.norm)
            return xx + L.mlp_apply(lp["mlp"], h, cfg.mlp_kind, cfg.act), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, params["dec"])
        new_cache = None
    else:
        def body(xx, scanned):
            lp, kv, ck, cv = scanned
            h = L.norm_apply(lp["norm1"], xx, cfg.norm)
            y, kv2 = A.gqa_apply(lp["self"], h, cfg, positions=positions,
                                 cache=kv)
            xx = xx + y
            h = L.norm_apply(lp["norm_x"], xx, cfg.norm)
            y, _ = A.gqa_apply(lp["cross"], h, cfg, positions=positions,
                               kv_override=(ck, cv))
            xx = xx + y
            h = L.norm_apply(lp["norm2"], xx, cfg.norm)
            return xx + L.mlp_apply(lp["mlp"], h, cfg.mlp_kind, cfg.act), kv2

        x, self_kv = jax.lax.scan(
            body, x, (params["dec"], cache.self_kv, cache.cross_k,
                      cache.cross_v))
        new_cache = EncDecCache(self_kv, cache.cross_k, cache.cross_v)

    x = L.norm_apply(params["dec_norm"], x, cfg.norm)
    if logits_slice is not None:
        x = x[:, -logits_slice:]
    logits = x @ params["embed"].T
    logits = logits + _vocab_mask(cfg).astype(logits.dtype)
    return constrain(logits, L.DATA, None, L.MODEL), new_cache


def encdec_empty_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    hkv, hd = cfg.n_kv_heads, cfg.hd
    lz = cfg.n_layers
    z = jnp.zeros((lz, batch, max_len, hkv, hd), dtype)
    kv = A.KVCache(z, z, jnp.zeros((lz,), jnp.int32))
    ck = jnp.zeros((lz, batch, cfg.n_frames, hkv, hd), dtype)
    return EncDecCache(kv, ck, ck)
